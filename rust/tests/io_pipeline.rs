//! I/O pipeline integration: GRF synthesis → container file → epoch-0
//! hyperslab ingestion on the D×H×W grid → owner-mapped data store →
//! per-step redistribution → store-backed training (the functional
//! realization of the paper's Fig. 3, wired into §III-A training).

use hydra3d::comm::{world, CommBackend, Communicator, GradReduce};
use hydra3d::data::container::{write_dataset, write_label_dataset, Container};
use hydra3d::data::grf::{GrfConfig, GrfDataset};
use hydra3d::engine::hybrid::{train_hybrid, train_hybrid_store, HybridOpts,
                              InMemorySource, IoMode};
use hydra3d::engine::{LrSchedule, TrainReport};
use hydra3d::iosim::store::{assignments_of, DataStore};
use hydra3d::partition::{GridTopology, SpatialGrid};
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use hydra3d::util::prop;
use hydra3d::util::rng::Pcg;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn tmpfile(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("hydra3d-io-{name}-{}", std::process::id()));
    p
}

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn has_grid_plan(rt: &RuntimeHandle, model: &str, grid: &SpatialGrid) -> bool {
    match rt.manifest().model(model) {
        Ok(info) => info.hybrid_plan(grid).is_ok(),
        Err(_) => false,
    }
}

/// Epoch-0 ingestion reads each input byte of the dataset exactly once
/// across all ranks (spatially-parallel ingestion: no redundant reads), and
/// the union of rank caches is the full dataset.
#[test]
fn epoch0_ingestion_is_exactly_once() {
    let ds = GrfDataset::generate(&GrfConfig { size: 8, seed: 3 }, 6);
    let path = tmpfile("ingest");
    write_dataset(&path, &ds.inputs, &ds.targets, None).unwrap();
    let c = Arc::new(Container::open(&path).unwrap());

    let topo = GridTopology::new(3, SpatialGrid::depth(2)); // 3 groups x 2-way
    let mut stores = Vec::new();
    for r in 0..topo.world_size() {
        stores.push(DataStore::ingest(&c, topo, r, false).unwrap());
    }
    // each group owns 2 of 6 samples; each rank caches its depth half
    for st in &stores {
        assert_eq!(st.cached(), 2);
    }
    // input voxels read exactly once in total; targets once per position;
    // the per-store geometric accounting agrees with the PFS byte counter
    let total_bytes: u64 = stores.iter().map(|s| s.ingest_bytes).sum();
    let vol_bytes = 6 * 8 * 8 * 8 * 4;
    let target_bytes = 6 * 4 * 4 * 2;
    assert_eq!(total_bytes, vol_bytes + target_bytes);
    assert_eq!(c.bytes_read.load(Ordering::Relaxed), total_bytes);

    // shard contents match the source dataset
    for st in &stores {
        let (group, _) = topo.coords_of(st.rank);
        for s in st.owner.samples_of(group) {
            let (x, t) = st.cache_entry(s).unwrap();
            assert_eq!(x, &ds.inputs[s].block3(st.shard_off, st.shard_len));
            assert_eq!(t.data(), ds.targets[s].data());
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Property: on random (possibly non-divisible) grids and group counts,
/// the union of all ranks' epoch-0 ingests covers every sample exactly
/// once per grid position — each voxel of each sample is cached by exactly
/// one rank of the owning group, with the correct contents.
#[test]
fn prop_ingest_union_covers_every_sample_once() {
    prop::check("ingest-union-cover", 12, |g| {
        let grid = SpatialGrid::new(g.usize_in(1, 2), g.usize_in(1, 2),
                                    g.usize_in(1, 2));
        let groups = g.usize_in(1, 3);
        let size = g.usize_in(4, 9); // often not divisible by the grid
        let n = g.usize_in(1, 5);
        let topo = GridTopology::new(groups, grid);
        let ds = GrfDataset::generate(&GrfConfig { size, seed: 7 }, n);
        let path = tmpfile(&format!("prop-ingest-{}", g.case));
        write_dataset(&path, &ds.inputs, &ds.targets, None)
            .map_err(|e| e.to_string())?;
        let c = Container::open(&path).map_err(|e| e.to_string())?;
        let stores: Vec<DataStore> = (0..topo.world_size())
            .map(|r| DataStore::ingest(&c, topo, r, false))
            .collect::<anyhow::Result<_>>()
            .map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();

        let vol = size * size * size;
        for (s, input) in ds.inputs.iter().enumerate() {
            let mut covered = vec![0u8; vol];
            for st in &stores {
                let (group, _) = topo.coords_of(st.rank);
                if st.owner.owner_group(s) != group {
                    if st.cache_entry(s).is_some() {
                        return Err(format!("rank {} cached unowned sample {s}",
                                           st.rank));
                    }
                    continue;
                }
                let (x, _) = st.cache_entry(s).ok_or_else(|| {
                    format!("rank {} missing owned sample {s}", st.rank)
                })?;
                if x != &input.block3(st.shard_off, st.shard_len) {
                    return Err(format!("rank {} sample {s}: wrong shard",
                                       st.rank));
                }
                for d in st.shard_off[0]..st.shard_off[0] + st.shard_len[0] {
                    for h in st.shard_off[1]..st.shard_off[1] + st.shard_len[1] {
                        for w in st.shard_off[2]..st.shard_off[2] + st.shard_len[2] {
                            covered[(d * size + h) * size + w] += 1;
                        }
                    }
                }
            }
            if !covered.iter().all(|&v| v == 1) {
                return Err(format!(
                    "grid {grid} groups {groups} size {size}: sample {s} not \
                     covered exactly once"));
            }
        }
        // every input byte ingested exactly once, one target per position
        let total: u64 = stores.iter().map(|st| st.ingest_bytes).sum();
        let expect = (n * vol * 4 + n * 4 * 4 * grid.ways()) as u64;
        if total != expect {
            return Err(format!("ingest bytes {total} != {expect}"));
        }
        Ok(())
    });
}

/// Property: after redistribution, every rank's staged shards are
/// bit-identical to direct container reads of its (D, H, W) block — on
/// random grids, group counts and assignments.
#[test]
fn prop_staged_shards_equal_direct_reads() {
    prop::check("staged-equals-direct", 8, |g| {
        let grid = SpatialGrid::new(g.usize_in(1, 2), g.usize_in(1, 2),
                                    g.usize_in(1, 2));
        let groups = g.usize_in(1, 3);
        let size = g.usize_in(4, 8);
        let n = g.usize_in(1, 4);
        let topo = GridTopology::new(groups, grid);
        let ds = GrfDataset::generate(&GrfConfig { size, seed: 11 }, n);
        let path = tmpfile(&format!("prop-staged-{}", g.case));
        write_dataset(&path, &ds.inputs, &ds.targets, None)
            .map_err(|e| e.to_string())?;
        let c = Arc::new(Container::open(&path).map_err(|e| e.to_string())?);
        // one random step: every group consumes a random sample
        let assignments: Vec<Vec<usize>> =
            (0..groups).map(|_| vec![g.usize_in(0, n - 1)]).collect();

        let eps = world(topo.world_size());
        let outs: Vec<Result<Vec<(usize, Tensor)>, String>> =
            std::thread::scope(|s| {
                eps.into_iter()
                    .enumerate()
                    .map(|(r, ep)| {
                        let c = c.clone();
                        let assignments = assignments.clone();
                        s.spawn(move || {
                            let mut st = DataStore::ingest(&c, topo, r, false)
                                .map_err(|e| e.to_string())?;
                            st.redistribute(&ep, &assignments)
                                .map_err(|e| e.to_string())?;
                            let (group, _) = topo.coords_of(r);
                            assignments[group]
                                .iter()
                                .map(|&smp| st.staged_shard(smp)
                                     .map(|(x, _)| (smp, x.clone()))
                                     .map_err(|e| e.to_string()))
                                .collect()
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
        std::fs::remove_file(&path).ok();
        for (r, got) in outs.into_iter().enumerate() {
            let (_, pos) = topo.coords_of(r);
            let (off, len) = grid.shard_of(size, pos);
            for (smp, x) in got? {
                if x != ds.inputs[smp].block3(off, len) {
                    return Err(format!("rank {r} sample {smp}: staged shard \
                                        != direct read"));
                }
            }
        }
        Ok(())
    });
}

/// Steady-state redistribution: after `redistribute`, every rank holds the
/// shards of the samples its group is about to train on, moved only over
/// the communicator (zero PFS reads), with the volume visible in both the
/// store counters and the world's `Redist` byte counter.
#[test]
fn steady_state_redistribution() {
    let ds = GrfDataset::generate(&GrfConfig { size: 8, seed: 4 }, 4);
    let path = tmpfile("redist");
    write_dataset(&path, &ds.inputs, &ds.targets, None).unwrap();
    let c = Arc::new(Container::open(&path).unwrap());

    let topo = GridTopology::new(2, SpatialGrid::depth(2));
    // step assignment: group 0 trains on sample 3, group 1 on sample 0 —
    // both owned by the *other* group (owner = sample % 2).
    let assignments = vec![vec![3usize], vec![0usize]];

    let eps = world(topo.world_size());
    let world_counters = eps[0].counters().clone();
    let results: Vec<(u64, Vec<(usize, Tensor)>)> = std::thread::scope(|s| {
        eps.into_iter()
            .enumerate()
            .map(|(r, ep)| {
                let c = c.clone();
                let assignments = assignments.clone();
                s.spawn(move || {
                    let mut st = DataStore::ingest(&c, topo, r, false).unwrap();
                    // all ranks finish ingesting before we snapshot the
                    // (shared) PFS byte counter
                    let all: Vec<usize> = (0..topo.world_size()).collect();
                    ep.barrier(&all).unwrap();
                    let before = c.bytes_read.load(Ordering::Relaxed);
                    st.redistribute(&ep, &assignments).unwrap();
                    let after = c.bytes_read.load(Ordering::Relaxed);
                    assert_eq!(before, after, "redistribution must not hit PFS");
                    let (group, _) = topo.coords_of(r);
                    let got: Vec<_> = assignments[group]
                        .iter()
                        .map(|&smp| (smp, st.staged_shard(smp).unwrap().0.clone()))
                        .collect();
                    (st.redist_bytes, got)
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });

    for (r, (_, got)) in results.iter().enumerate() {
        let (_, pos) = topo.coords_of(r);
        let (off, len) = topo.grid.shard_of(8, pos);
        for (smp, x) in got {
            assert_eq!(x, &ds.inputs[*smp].block3(off, len),
                       "rank {r} sample {smp}");
        }
    }
    // both owner groups sent their shards: 2 samples x 2 positions, each a
    // (1,1,4,8,8) shard + a 4-f32 target
    let total: u64 = results.iter().map(|(b, _)| b).sum();
    assert_eq!(total, 4 * (256 + 4) * 4);
    // ... and the world counters saw exactly the same Redist volume
    assert_eq!(world_counters.redist_bytes(), total);
    std::fs::remove_file(&path).ok();
}

/// A self-owned assignment needs no communication.
#[test]
fn self_owned_assignment_is_local() {
    let ds = GrfDataset::generate(&GrfConfig { size: 8, seed: 5 }, 2);
    let path = tmpfile("local");
    write_dataset(&path, &ds.inputs, &ds.targets, None).unwrap();
    let c = Arc::new(Container::open(&path).unwrap());
    let topo = GridTopology::new(2, SpatialGrid::depth(1));
    let assignments = vec![vec![0usize], vec![1usize]]; // owner == consumer
    let eps = world(2);
    std::thread::scope(|s| {
        for (r, ep) in eps.into_iter().enumerate() {
            let c = c.clone();
            let assignments = assignments.clone();
            s.spawn(move || {
                let mut st = DataStore::ingest(&c, topo, r, false).unwrap();
                st.redistribute(&ep, &assignments).unwrap();
                assert_eq!(st.redist_bytes, 0, "no traffic for self-owned samples");
            });
        }
    });
    std::fs::remove_file(&path).ok();
}

/// Label-mode store on a true 3D grid: U-Net style spatially partitioned
/// ground truth (the paper: "we also spatially distribute the ground-truth
/// segmentation") cached as (D, H, W) blocks.
#[test]
fn label_mode_store_caches_label_shards() {
    let (inputs, labels) = hydra3d::data::ct::ct_dataset(8, 2, 2, 7);
    let targets: Vec<Tensor> = (0..2).map(|_| Tensor::zeros(&[1, 1])).collect();
    let path = tmpfile("labels");
    write_dataset(&path, &inputs, &targets, Some(&labels)).unwrap();
    let c = Container::open(&path).unwrap();
    let topo = GridTopology::new(1, SpatialGrid::new(2, 2, 1));
    for r in 0..topo.world_size() {
        let st = DataStore::ingest(&c, topo, r, true).unwrap();
        let (group, _) = topo.coords_of(r);
        for s in st.owner.samples_of(group) {
            let (x, l) = st.cache_entry(s).unwrap();
            assert_eq!(x, &inputs[s].block3(st.shard_off, st.shard_len));
            assert_eq!(l, &labels[s].block3(st.shard_off, st.shard_len));
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Container-as-SampleSource: direct epoch-0 training path reads shards
/// (depth slabs and native 3D blocks) straight from the file.
#[test]
fn container_is_a_sample_source() {
    use hydra3d::engine::hybrid::SampleSource;
    let ds = GrfDataset::generate(&GrfConfig { size: 8, seed: 6 }, 3);
    let path = tmpfile("source");
    write_dataset(&path, &ds.inputs, &ds.targets, None).unwrap();
    let c = Container::open(&path).unwrap();
    assert_eq!(SampleSource::len(&c), 3);
    let shard = c.input_shard(1, 2, 4).unwrap();
    assert_eq!(shard, ds.inputs[1].slice_ax(2, 2, 4));
    // native 3D block path (no slab-then-crop)
    let block = SampleSource::input_shard3(&c, 1, [2, 0, 4], [4, 4, 4]).unwrap();
    assert_eq!(block, ds.inputs[1].block3([2, 0, 4], [4, 4, 4]));
    assert_eq!(c.target_full(2).unwrap().data(), ds.targets[2].data());
    std::fs::remove_file(&path).ok();
}

/// Schedule rows split group-major, matching the engine's slot layout.
#[test]
fn schedule_assignments_match_engine_slots() {
    let row = [9usize, 8, 7, 6];
    let a = assignments_of(&row, 2);
    assert_eq!(a, vec![vec![9, 8], vec![7, 6]]);
}

// ---------------------------------------------------------------------------
// Store-backed training equivalence (artifact-gated, like the engine tests)
// ---------------------------------------------------------------------------

fn make_cf_data(n: usize, size: usize, seed: u64) -> (Vec<Tensor>, Vec<Tensor>) {
    let mut rng = Pcg::new(seed, 77);
    let mut inputs = Vec::new();
    let mut targets = Vec::new();
    for _ in 0..n {
        let mut x = Tensor::zeros(&[1, 1, size, size, size]);
        rng.fill_normal(x.data_mut(), 1.0);
        let m: f32 = x.data().iter().sum::<f32>() / x.numel() as f32;
        let s: f32 = x.data().iter().map(|v| v * v).sum::<f32>() / x.numel() as f32;
        inputs.push(x);
        targets.push(Tensor::from_vec(&[1, 4], vec![m, s, -m, 0.3]));
    }
    (inputs, targets)
}

fn assert_bit_identical(a: &TrainReport, b: &TrainReport, what: &str) {
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert!(ra.loss.to_bits() == rb.loss.to_bits(),
                "{what}: step {} loss {} vs {}", ra.step, ra.loss, rb.loss);
    }
    for (i, (pa, pb)) in a.params.iter().zip(&b.params).enumerate() {
        assert!(pa.data() == pb.data(), "{what}: param {i} differs");
    }
}

/// THE acceptance claim: `train_hybrid` fed by the store (blocking and
/// async) on a 2x2x2 grid x 2 groups is *bit-identical* to the
/// InMemorySource — the store moves bytes, never values — and epochs 1+
/// never touch the container (every byte read is epoch-0 ingestion).
#[test]
fn store_training_bit_identical_cosmoflow_2x2x2() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let grid = SpatialGrid::new(2, 2, 2);
    if !has_grid_plan(&rt, "cf-nano", &grid) {
        eprintln!("(artifacts predate grid shard sets; rebuild with \
                   `make artifacts` to run the store equivalence test)");
        return;
    }
    let (inputs, targets) = make_cf_data(6, 8, 31);
    let steps = 7; // 14 draws over 6 samples: the schedule crosses 2 epochs
    let opts = HybridOpts {
        model: "cf-nano".into(),
        grid,
        groups: 2,
        batch_global: 2,
        steps,
        seed: 21,
        schedule: LrSchedule { lr0: 2e-3, floor_frac: 0.1, total_steps: steps },
        log_every: 0,
        ckpt: None,
    };
    let inmem = train_hybrid(&rt, &opts, Arc::new(InMemorySource {
        inputs: inputs.clone(),
        targets: targets.clone(),
    })).unwrap();

    let path = tmpfile("equiv-cf");
    write_dataset(&path, &inputs, &targets, None).unwrap();
    for mode in [IoMode::Store, IoMode::StoreAsync] {
        let c = Arc::new(Container::open(&path).unwrap());
        let rep = train_hybrid_store(&rt, &opts, c.clone(), mode,
                                     &CommBackend::Channel,
                                     GradReduce::default())
            .unwrap();
        assert_bit_identical(&inmem, &rep, mode.name());
        // epochs 1+ perform zero container reads: the run's total PFS
        // traffic is exactly the epoch-0 ingest (dataset once + targets
        // once per grid position), nothing more
        let read = c.bytes_read.load(Ordering::Relaxed);
        assert_eq!(read, rep.ingest_bytes, "{}: reads beyond ingestion",
                   mode.name());
        let expect = (6 * 8 * 8 * 8 * 4 + 6 * 4 * 4 * grid.ways()) as u64;
        assert_eq!(rep.ingest_bytes, expect, "{}: ingest bytes", mode.name());
        assert!(rep.redist_bytes > 0, "{}: no staging traffic", mode.name());
        if mode == IoMode::StoreAsync {
            assert!(rep.io_overlapped > 0.0, "async staging did no worker work");
        }
    }
    std::fs::remove_file(&path).ok();
}

/// The same bit-identity for the U-Net workload: spatially partitioned
/// one-hot ground truth staged through the store on a 2x2x2 grid.
#[test]
fn store_training_bit_identical_unet_2x2x2() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let grid = SpatialGrid::new(2, 2, 2);
    if !has_grid_plan(&rt, "unet16", &grid) {
        eprintln!("(artifacts predate grid shard sets; rebuild with \
                   `make artifacts` to run the U-Net store test)");
        return;
    }
    let (inputs, labels) = hydra3d::data::ct::ct_dataset(16, 2, 4, 99);
    let steps = 5; // 5 draws over 4 scans: crosses an epoch boundary
    let opts = HybridOpts {
        model: "unet16".into(),
        grid,
        groups: 1,
        batch_global: 1,
        steps,
        seed: 5,
        schedule: LrSchedule { lr0: 2e-3, floor_frac: 0.1, total_steps: steps },
        log_every: 0,
        ckpt: None,
    };
    let inmem = train_hybrid(&rt, &opts, Arc::new(InMemorySource {
        inputs: inputs.clone(),
        targets: labels.clone(),
    })).unwrap();

    let path = tmpfile("equiv-unet");
    write_label_dataset(&path, &inputs, &labels).unwrap();
    let c = Arc::new(Container::open(&path).unwrap());
    let rep = train_hybrid_store(&rt, &opts, c.clone(), IoMode::StoreAsync,
                                 &CommBackend::Channel, GradReduce::default())
        .unwrap();
    assert_bit_identical(&inmem, &rep, "unet store-async");
    let read = c.bytes_read.load(Ordering::Relaxed);
    assert_eq!(read, rep.ingest_bytes, "reads beyond ingestion");
    std::fs::remove_file(&path).ok();
}
