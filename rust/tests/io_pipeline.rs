//! I/O pipeline integration: GRF synthesis → container file → epoch-0
//! hyperslab ingestion → owner-mapped data store → per-step redistribution
//! (the functional realization of the paper's Fig. 3).

use hydra3d::comm::{world, Communicator};
use hydra3d::data::container::{write_dataset, Container};
use hydra3d::data::grf::{GrfConfig, GrfDataset};
use hydra3d::iosim::store::DataStore;
use hydra3d::partition::Topology;
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn tmpfile(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("hydra3d-io-{name}-{}", std::process::id()));
    p
}

/// Epoch-0 ingestion reads each input byte of the dataset exactly once
/// across all ranks (spatially-parallel ingestion: no redundant reads), and
/// the union of rank caches is the full dataset.
#[test]
fn epoch0_ingestion_is_exactly_once() {
    let ds = GrfDataset::generate(&GrfConfig { size: 8, seed: 3 }, 6);
    let path = tmpfile("ingest");
    write_dataset(&path, &ds.inputs, &ds.targets, None).unwrap();
    let c = Arc::new(Container::open(&path).unwrap());

    let topo = Topology::new(3, 2); // 3 groups x 2-way depth
    let mut stores = Vec::new();
    for r in 0..topo.world_size() {
        stores.push(DataStore::ingest(&c, topo, r, false).unwrap());
    }
    // each group owns 2 of 6 samples; each rank caches its depth half
    for st in &stores {
        assert_eq!(st.cached(), 2);
    }
    // input voxels read exactly once in total; targets once per position
    let total_bytes: u64 = stores.iter().map(|s| s.ingest_bytes).sum();
    let vol_bytes = 6 * 8 * 8 * 8 * 4;
    let target_bytes = 6 * 4 * 4 * 2;
    assert_eq!(total_bytes, vol_bytes + target_bytes);

    // shard contents match the source dataset
    for st in &stores {
        let (group, pos) = topo.coords_of(st.rank);
        for s in st.owner.samples_of(group) {
            let (x, t) = st.cache_entry(s).unwrap();
            assert_eq!(x, &ds.inputs[s].slice_d(pos * 4, 4));
            assert_eq!(t.data(), ds.targets[s].data());
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Steady-state redistribution: after `redistribute`, every rank holds the
/// shards of the samples its group is about to train on, moved only over
/// the communicator (zero PFS reads).
#[test]
fn steady_state_redistribution() {
    let ds = GrfDataset::generate(&GrfConfig { size: 8, seed: 4 }, 4);
    let path = tmpfile("redist");
    write_dataset(&path, &ds.inputs, &ds.targets, None).unwrap();
    let c = Arc::new(Container::open(&path).unwrap());

    let topo = Topology::new(2, 2);
    // step assignment: group 0 trains on sample 3, group 1 on sample 0 —
    // both owned by the *other* group (owner = sample % 2).
    let assignments = vec![vec![3usize], vec![0usize]];

    let eps = world(topo.world_size());
    let results: Vec<(u64, Vec<(usize, hydra3d::tensor::Tensor)>)> =
        std::thread::scope(|s| {
            eps.into_iter()
                .enumerate()
                .map(|(r, ep)| {
                    let c = c.clone();
                    let assignments = assignments.clone();
                    s.spawn(move || {
                        let mut st = DataStore::ingest(&c, topo, r, false).unwrap();
                        // all ranks finish ingesting before we snapshot the
                        // (shared) PFS byte counter
                        let all: Vec<usize> = (0..topo.world_size()).collect();
                        ep.barrier(&all).unwrap();
                        let before = c.bytes_read.load(Ordering::Relaxed);
                        st.redistribute(&ep, &assignments).unwrap();
                        let after = c.bytes_read.load(Ordering::Relaxed);
                        assert_eq!(before, after, "redistribution must not hit PFS");
                        let (group, _) = topo.coords_of(r);
                        let got: Vec<_> = assignments[group]
                            .iter()
                            .map(|&smp| (smp, st.staged_shard(smp).unwrap().0.clone()))
                            .collect();
                        (st.redist_bytes, got)
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });

    for (r, (_, got)) in results.iter().enumerate() {
        let (_, pos) = topo.coords_of(r);
        for (smp, x) in got {
            assert_eq!(x, &ds.inputs[*smp].slice_d(pos * 4, 4),
                       "rank {r} sample {smp}");
        }
    }
    // both owner groups sent their shards: nonzero redistribution traffic
    let total: u64 = results.iter().map(|(b, _)| b).sum();
    assert!(total > 0);
    std::fs::remove_file(&path).ok();
}

/// A self-owned assignment needs no communication.
#[test]
fn self_owned_assignment_is_local() {
    let ds = GrfDataset::generate(&GrfConfig { size: 8, seed: 5 }, 2);
    let path = tmpfile("local");
    write_dataset(&path, &ds.inputs, &ds.targets, None).unwrap();
    let c = Arc::new(Container::open(&path).unwrap());
    let topo = Topology::new(2, 1);
    let assignments = vec![vec![0usize], vec![1usize]]; // owner == consumer
    let eps = world(2);
    std::thread::scope(|s| {
        for (r, ep) in eps.into_iter().enumerate() {
            let c = c.clone();
            let assignments = assignments.clone();
            s.spawn(move || {
                let mut st = DataStore::ingest(&c, topo, r, false).unwrap();
                st.redistribute(&ep, &assignments).unwrap();
                assert_eq!(st.redist_bytes, 0, "no traffic for self-owned samples");
            });
        }
    });
    std::fs::remove_file(&path).ok();
}

/// Label-mode store: U-Net style spatially partitioned ground truth
/// (the paper: "we also spatially distribute the ground-truth
/// segmentation").
#[test]
fn label_mode_store_caches_label_shards() {
    let (inputs, labels) = hydra3d::data::ct::ct_dataset(8, 2, 2, 7);
    let targets: Vec<hydra3d::tensor::Tensor> =
        (0..2).map(|_| hydra3d::tensor::Tensor::zeros(&[1, 1])).collect();
    let path = tmpfile("labels");
    write_dataset(&path, &inputs, &targets, Some(&labels)).unwrap();
    let c = Container::open(&path).unwrap();
    let topo = Topology::new(1, 2);
    let st = DataStore::ingest(&c, topo, 1, true).unwrap();
    let (group, pos) = topo.coords_of(1);
    for s in st.owner.samples_of(group) {
        let (x, l) = st.cache_entry(s).unwrap();
        assert_eq!(x, &inputs[s].slice_d(pos * 4, 4));
        assert_eq!(l, &labels[s].slice_d(pos * 4, 4));
    }
    std::fs::remove_file(&path).ok();
}

/// Container-as-SampleSource: direct epoch-0 training path reads shards
/// straight from the file.
#[test]
fn container_is_a_sample_source() {
    use hydra3d::engine::hybrid::SampleSource;
    let ds = GrfDataset::generate(&GrfConfig { size: 8, seed: 6 }, 3);
    let path = tmpfile("source");
    write_dataset(&path, &ds.inputs, &ds.targets, None).unwrap();
    let c = Container::open(&path).unwrap();
    assert_eq!(SampleSource::len(&c), 3);
    let shard = c.input_shard(1, 2, 4).unwrap();
    assert_eq!(shard, ds.inputs[1].slice_d(2, 4));
    assert_eq!(c.target_full(2).unwrap().data(), ds.targets[2].data());
    std::fs::remove_file(&path).ok();
}
