//! Fault tolerance acceptance: bit-exact checkpoint/restart.
//!
//! The contract under test (ROADMAP item 5a): a run that is interrupted
//! and resumed from its newest committed snapshot produces the *same bits*
//! as the uninterrupted run — loss bit patterns, parameters, BN running
//! statistics — across the {channel, socket} x {inmem, store-async}
//! backend/IO matrix, and a torn (truncated) snapshot is rejected in favor
//! of the previous committed marker.
//!
//! The process-level half exercises the real failure path: a 4-process
//! `train --backend socket` run whose node 1 is killed mid-training
//! (`HYDRA3D_TEST_DIE_NODE` + `HYDRA3D_TEST_DIE_AT_STEP`), auto-restarted
//! by `--max-restarts`, and required to report the identical loss
//! trajectory — plus byte counters identical to a clean resume performing
//! the same recovery computation.

use hydra3d::comm::{CommBackend, GradReduce};
use hydra3d::engine::hybrid::{train_hybrid_store, train_hybrid_with,
                              HybridOpts, InMemorySource, IoMode};
use hydra3d::engine::{LrSchedule, TrainReport};
use hydra3d::data::container::{write_dataset, Container};
use hydra3d::partition::SpatialGrid;
use hydra3d::runtime::checkpoint::{self, CheckpointCfg};
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use hydra3d::util::json::Json;
use hydra3d::util::rng::Pcg;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::process::Stdio;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("hydra3d-ckpt-it-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn make_cf_data(n: usize, size: usize, seed: u64) -> (Vec<Tensor>, Vec<Tensor>) {
    let mut rng = Pcg::new(seed, 77);
    let mut inputs = Vec::new();
    let mut targets = Vec::new();
    for _ in 0..n {
        let mut x = Tensor::zeros(&[1, 1, size, size, size]);
        rng.fill_normal(x.data_mut(), 1.0);
        let m: f32 = x.data().iter().sum::<f32>() / x.numel() as f32;
        let s: f32 = x.data().iter().map(|v| v * v).sum::<f32>() / x.numel() as f32;
        inputs.push(x);
        targets.push(Tensor::from_vec(&[1, 4], vec![m, s, -m, 0.3]));
    }
    (inputs, targets)
}

fn opts(steps: usize, ckpt: Option<CheckpointCfg>) -> HybridOpts {
    HybridOpts {
        model: "cf-nano".into(),
        grid: SpatialGrid::depth(2),
        groups: 2,
        batch_global: 2,
        steps,
        seed: 21,
        schedule: LrSchedule { lr0: 2e-3, floor_frac: 0.1, total_steps: steps },
        log_every: 0,
        ckpt,
    }
}

fn cfg(dir: &Path, resume: bool) -> Option<CheckpointCfg> {
    Some(CheckpointCfg { dir: dir.to_path_buf(), every: 2, resume })
}

/// Loss bit patterns, parameter bits and BN running-stat bits must all
/// match; byte counters are deliberately excluded (a resumed report covers
/// only the resumed suffix's traffic).
fn assert_state_bits_equal(a: &TrainReport, b: &TrainReport, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record counts");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.step, rb.step, "{what}: step ids");
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(),
                   "{what}: step {} loss {:.9} vs {:.9}", ra.step, ra.loss,
                   rb.loss);
        assert_eq!(ra.lr.to_bits(), rb.lr.to_bits(), "{what}: step {} lr",
                   ra.step);
    }
    for (i, (pa, pb)) in a.params.iter().zip(&b.params).enumerate() {
        let same = pa.data().len() == pb.data().len()
            && pa.data().iter().zip(pb.data())
                .all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same, "{what}: param {i} bit patterns differ");
    }
    for (side, (ta, tb)) in [
        (&a.running.0, &b.running.0),
        (&a.running.1, &b.running.1),
    ]
    .iter()
    .enumerate()
    {
        for (i, (x, y)) in ta.iter().zip(tb.iter()).enumerate() {
            let same = x.data().iter().zip(y.data())
                .all(|(u, v)| u.to_bits() == v.to_bits());
            assert!(same, "{what}: running stat {side}/{i} differs");
        }
    }
}

/// THE acceptance matrix: resume-equals-uninterrupted, bit for bit, over
/// {channel, socket} transports x {inmem, store-async} I/O. Each cell runs
/// the full trajectory with snapshots every 2 steps, deletes the later
/// snapshots to stand in for an interruption after step 2, resumes, and
/// requires the resumed run's full trajectory and final state to match the
/// uninterrupted run exactly.
#[test]
fn resume_equals_uninterrupted_across_backend_io_matrix() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let steps = 6;
    let (inputs, targets) = make_cf_data(6, 8, 31);

    let backends: [(&str, CommBackend); 2] = [
        ("channel", CommBackend::Channel),
        ("socket", CommBackend::Socket { ranks_per_node: 2 }),
    ];
    for (bname, backend) in backends {
        for io in ["inmem", "store-async"] {
            let what = format!("{bname}/{io}");
            let ck = scratch(&format!("matrix-{bname}-{io}"));
            let run = |resume: bool| -> TrainReport {
                let o = opts(steps, cfg(&ck, resume));
                match io {
                    "inmem" => {
                        let src = Arc::new(InMemorySource {
                            inputs: inputs.clone(),
                            targets: targets.clone(),
                        });
                        train_hybrid_with(&rt, &o, src, &backend,
                                          GradReduce::default())
                            .unwrap_or_else(|e| panic!("{what}: {e:#}"))
                    }
                    _ => {
                        let path = ck.join("dataset.bin");
                        if !path.exists() {
                            write_dataset(&path, &inputs, &targets, None)
                                .unwrap();
                        }
                        let c = Arc::new(Container::open(&path).unwrap());
                        train_hybrid_store(&rt, &o, c, IoMode::StoreAsync,
                                           &backend, GradReduce::default())
                            .unwrap_or_else(|e| panic!("{what}: {e:#}"))
                    }
                }
            };

            let full = run(false);
            assert_eq!(full.records.len(), steps, "{what}: baseline steps");
            // every cadence point must have committed: steps 2, 4 and 6
            assert_eq!(checkpoint::committed_steps(&ck), vec![6, 4, 2],
                       "{what}: committed snapshots");

            // resume over the complete directory is a no-op replay: the
            // final snapshot already holds the whole trajectory
            let noop = run(true);
            assert_state_bits_equal(&full, &noop, &format!("{what} (noop)"));

            // interruption stand-in: only the step-2 snapshot survives
            std::fs::remove_dir_all(checkpoint::step_dir(&ck, 4)).unwrap();
            std::fs::remove_dir_all(checkpoint::step_dir(&ck, 6)).unwrap();
            let resumed = run(true);
            assert_state_bits_equal(&full, &resumed, &what);

            std::fs::remove_dir_all(&ck).ok();
        }
    }
}

/// Torn-write recovery at the engine level: with the newest snapshot
/// destroyed and the next-newest torn (rank 1's shard truncated
/// mid-payload), a resuming world must fall back to the oldest committed
/// marker and still reproduce the uninterrupted bits.
#[test]
fn resume_falls_back_past_torn_snapshot() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let steps = 6;
    let (inputs, targets) = make_cf_data(6, 8, 31);
    let src = Arc::new(InMemorySource { inputs, targets });
    let ck = scratch("torn");

    let full = train_hybrid_with(&rt, &opts(steps, cfg(&ck, false)), src.clone(),
                                 &CommBackend::Channel, GradReduce::default())
        .unwrap();

    std::fs::remove_dir_all(checkpoint::step_dir(&ck, 6)).unwrap();
    let victim = checkpoint::shard_path(&checkpoint::step_dir(&ck, 4), 1);
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();

    let resumed = train_hybrid_with(&rt, &opts(steps, cfg(&ck, true)), src,
                                    &CommBackend::Channel, GradReduce::default())
        .unwrap();
    assert_state_bits_equal(&full, &resumed, "torn fallback");
    std::fs::remove_dir_all(&ck).ok();
}

/// A snapshot of a different configuration must never seed a run: flip the
/// seed and the resuming world has to start fresh (and still complete).
#[test]
fn fingerprint_mismatch_starts_fresh() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let steps = 4;
    let (inputs, targets) = make_cf_data(6, 8, 31);
    let src = Arc::new(InMemorySource { inputs, targets });
    let ck = scratch("fp-mismatch");

    train_hybrid_with(&rt, &opts(steps, cfg(&ck, false)), src.clone(),
                      &CommBackend::Channel, GradReduce::default())
        .unwrap();

    let mut other = opts(steps, cfg(&ck, true));
    other.seed = 99;
    let mut fresh = opts(steps, None);
    fresh.seed = 99;
    let resumed = train_hybrid_with(&rt, &other, src.clone(),
                                    &CommBackend::Channel,
                                    GradReduce::default())
        .unwrap();
    let baseline = train_hybrid_with(&rt, &fresh, src, &CommBackend::Channel,
                                     GradReduce::default())
        .unwrap();
    assert_state_bits_equal(&baseline, &resumed, "fingerprint mismatch");
    std::fs::remove_dir_all(&ck).ok();
}

// ---------------------------------------------------------------------------
// process-level fault injection (the CI fault lane's assertions, in-tree)
// ---------------------------------------------------------------------------

fn hydra3d_bin() -> std::process::Command {
    std::process::Command::new(env!("CARGO_BIN_EXE_hydra3d"))
}

fn wait_with_deadline(
    mut child: std::process::Child,
    secs: u64,
    what: &str,
) -> (std::process::ExitStatus, String, String) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    let status = loop {
        match child.try_wait().expect("try_wait") {
            Some(st) => break st,
            None if Instant::now() >= deadline => {
                child.kill().ok();
                child.wait().ok();
                panic!("{what} still running after {secs}s — launcher hung");
            }
            None => std::thread::sleep(Duration::from_millis(50)),
        }
    };
    let mut out = String::new();
    let mut err = String::new();
    if let Some(mut o) = child.stdout.take() {
        o.read_to_string(&mut out).ok();
    }
    if let Some(mut e) = child.stderr.take() {
        e.read_to_string(&mut err).ok();
    }
    (status, out, err)
}

/// Copy one committed snapshot directory (shards + meta + marker).
fn copy_snapshot(from: &Path, to: &Path) {
    std::fs::create_dir_all(to).unwrap();
    for e in std::fs::read_dir(from).unwrap().flatten() {
        std::fs::copy(e.path(), to.join(e.file_name())).unwrap();
    }
}

/// Kill-at-step recovery, end to end over real processes: node 1 of a
/// 4-rank / 2-node socket world dies at step 3; `--max-restarts 1` detects
/// the dead world, kills the survivor, relaunches with resume forced on,
/// and the *reported trajectory is bit-identical to the uninterrupted
/// run's*. The killed-and-restarted run's byte counters are additionally
/// required to equal a clean `--resume` run performing the same recovery
/// computation from the same snapshot.
#[test]
fn socket_world_recovers_from_killed_node_bit_exact() {
    let Some(dir) = artifacts() else { return };
    let root = scratch("faultlane");
    let reports = root.join("reports");
    std::fs::create_dir_all(&reports).unwrap();

    let run = |tag: &str, ck: &Path, extra: &[&str], die_at: Option<usize>|
     -> (String, Json) {
        let report = reports.join(format!("{tag}.json"));
        let mut cmd = hydra3d_bin();
        cmd.args(["train", "--model", "cf-nano", "--ways", "2", "--groups",
                  "2", "--batch", "2", "--steps", "5", "--samples", "6",
                  "--seed", "12", "--ranks-per-node", "2", "--backend",
                  "socket", "--checkpoint-every", "2"])
            .args(["--checkpoint-dir", ck.to_str().unwrap()])
            .args(["--report", report.to_str().unwrap()])
            .args(extra)
            .env("HYDRA3D_ARTIFACTS", &dir)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped());
        if let Some(step) = die_at {
            cmd.env("HYDRA3D_TEST_DIE_NODE", "1")
                .env("HYDRA3D_TEST_DIE_AT_STEP", step.to_string());
        }
        let child = cmd.spawn().expect("spawn train --backend socket");
        let (status, out, err) = wait_with_deadline(child, 300, tag);
        assert!(status.success(), "{tag} failed\nstdout: {out}\nstderr: {err}");
        (out, Json::parse_file(&report).unwrap())
    };

    // A: uninterrupted baseline (snapshots at steps 2, 4 and final 5)
    let ck_a = root.join("ckpt-a");
    let (out_a, rep_a) = run("baseline", &ck_a, &[], None);
    assert!(out_a.contains("world restarts: 0"), "stdout: {out_a}");

    // B: node 1 killed at step 3, one auto-restart allowed
    let ck_b = root.join("ckpt-b");
    let (out_b, rep_b) = run("killed", &ck_b, &["--max-restarts", "1"],
                             Some(3));
    assert!(out_b.contains("world restarts: 1"),
            "recovery did not restart the world\nstdout: {out_b}");

    // C: clean resume from a copy of the snapshot B's restart recovered
    // from — the same computation B's second attempt performed
    let ck_c = root.join("ckpt-c");
    copy_snapshot(&ck_a.join("step-2"), &ck_c.join("step-2"));
    let (out_c, rep_c) = run("clean-resume", &ck_c, &["--resume"], None);
    assert!(out_c.contains("world restarts: 0"), "stdout: {out_c}");

    // recovered trajectory == uninterrupted trajectory, bit for bit
    for key in ["schema", "world", "losses_bits"] {
        assert_eq!(rep_a.req(key).unwrap(), rep_b.req(key).unwrap(),
                   "killed-and-recovered run diverged from baseline on {key}");
    }
    assert_eq!(rep_a.req("losses_bits").unwrap().as_arr().unwrap().len(), 5);
    // the restarted attempt's traffic == the clean resume's traffic: the
    // recovery performed exactly the deterministic resumed computation
    for key in ["schema", "world", "losses_bits", "comm_bytes", "halo_bytes",
                "ingest_bytes", "redist_bytes", "socket_frame_bytes"] {
        assert_eq!(rep_b.req(key).unwrap(), rep_c.req(key).unwrap(),
                   "recovered run's {key} differs from a clean resume");
    }
    std::fs::remove_dir_all(&root).ok();
}
