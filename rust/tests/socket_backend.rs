//! The multi-process socket backend's core contract: engines run
//! **unchanged** over it, and a socket-world run is *bit-identical* to the
//! channel-world run of the same configuration — same loss bit patterns,
//! same `comm_bytes`/`halo_bytes`/`ingest_bytes`/`redist_bytes` counters.
//! The transport round-trips every f32 through `to_le_bytes`/`from_le_bytes`
//! exactly and the trait-default collectives are shared between backends,
//! so any divergence is a transport bug, not float noise.
//!
//! Also under test here: the launcher's fail-fast supervision (a killed
//! worker must surface a clean error, never a hang on collectives that can
//! no longer complete) and the `comm-smoke` CLI's real 4-process run with
//! its deterministic inter-node frame counters.

use hydra3d::comm::{
    socket_world, world, CommBackend, Communicator, GradReduce,
    DEFAULT_BUCKET_ELEMS,
};
use hydra3d::engine::hybrid::{train_hybrid_with, HybridOpts, InMemorySource};
use hydra3d::engine::{LrSchedule, TrainReport};
use hydra3d::partition::SpatialGrid;
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use hydra3d::util::json::Json;
use hydra3d::util::prop;
use hydra3d::util::rng::Pcg;
use std::io::Read;
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn make_cf_data(n: usize, size: usize, seed: u64) -> (Vec<Tensor>, Vec<Tensor>) {
    let mut rng = Pcg::new(seed, 77);
    let mut inputs = Vec::new();
    let mut targets = Vec::new();
    for _ in 0..n {
        let mut x = Tensor::zeros(&[1, 1, size, size, size]);
        rng.fill_normal(x.data_mut(), 1.0);
        let m: f32 = x.data().iter().sum::<f32>() / x.numel() as f32;
        let s: f32 = x.data().iter().map(|v| v * v).sum::<f32>() / x.numel() as f32;
        inputs.push(x);
        targets.push(Tensor::from_vec(&[1, 4], vec![m, s, -m, 0.3]));
    }
    (inputs, targets)
}

fn opts(grid: SpatialGrid, groups: usize, batch: usize, steps: usize,
        seed: u64) -> HybridOpts {
    HybridOpts {
        model: "cf-nano".into(),
        grid,
        groups,
        batch_global: batch,
        steps,
        seed,
        schedule: LrSchedule { lr0: 2e-3, floor_frac: 0.1, total_steps: steps },
        log_every: 0,
        ckpt: None,
    }
}

/// Bit-for-bit report comparison: loss bit patterns, every parameter bit
/// pattern, and every byte counter except `socket_frame_bytes` (the only
/// field the transport is *allowed* to change).
fn bit_identical(a: &TrainReport, b: &TrainReport) -> Result<(), String> {
    if a.records.len() != b.records.len() {
        return Err(format!("{} vs {} steps", a.records.len(), b.records.len()));
    }
    for (ra, rb) in a.records.iter().zip(&b.records) {
        if ra.loss.to_bits() != rb.loss.to_bits() {
            return Err(format!("step {} loss {:.9} vs {:.9} (bits {:08x} vs \
                                {:08x})", ra.step, ra.loss, rb.loss,
                               ra.loss.to_bits(), rb.loss.to_bits()));
        }
    }
    for (i, (pa, pb)) in a.params.iter().zip(&b.params).enumerate() {
        let same = pa.data().len() == pb.data().len()
            && pa.data().iter().zip(pb.data())
                .all(|(x, y)| x.to_bits() == y.to_bits());
        if !same {
            return Err(format!("param {i} bit patterns differ"));
        }
    }
    if a.comm_bytes != b.comm_bytes {
        return Err(format!("comm_bytes {} vs {}", a.comm_bytes, b.comm_bytes));
    }
    if a.halo_bytes != b.halo_bytes {
        return Err(format!("halo_bytes {:?} vs {:?}", a.halo_bytes, b.halo_bytes));
    }
    if a.ingest_bytes != b.ingest_bytes || a.redist_bytes != b.redist_bytes {
        return Err("io byte counters differ".into());
    }
    Ok(())
}

/// In-process transport equality, no artifacts needed: the same collective
/// sequence over a channel world and a socket world (2 ranks per node)
/// must produce bitwise-identical buffers on every rank — the backends
/// share the trait-default algorithms and only move bytes.
#[test]
fn socket_collectives_bitwise_match_channel() {
    fn run<E: Communicator + Send>(eps: Vec<E>, len: usize) -> Vec<Vec<f32>> {
        let n = eps.len();
        std::thread::scope(|s| {
            let hs: Vec<_> = eps
                .into_iter()
                .map(|ep| {
                    let group: Vec<usize> = (0..n).collect();
                    s.spawn(move || {
                        let mut buf: Vec<f32> = (0..len)
                            .map(|i| {
                                let sign = if (ep.rank() + i) % 2 == 0 { 1.0 }
                                           else { -1.0f32 };
                                sign * ((ep.rank() + 2) as f32)
                                    .powi((i % 7) as i32 - 3)
                            })
                            .collect();
                        ep.allreduce_sum(&mut buf, &group).unwrap();
                        let bc = ep
                            .broadcast(vec![ep.rank() as f32 + 0.25; 5], &group)
                            .unwrap();
                        buf.extend_from_slice(&bc);
                        let ag = ep
                            .allgather(&[ep.rank() as f32 * 0.5; 3], &group)
                            .unwrap();
                        for part in ag {
                            buf.extend_from_slice(&part);
                        }
                        ep.barrier(&group).unwrap();
                        buf
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }
    for len in [1usize, 7, 1024] {
        let chan = run(world(4), len);
        let sock = run(socket_world(4, 2).unwrap(), len);
        for (r, (c, s)) in chan.iter().zip(&sock).enumerate() {
            assert!(
                c.iter().zip(s).all(|(x, y)| x.to_bits() == y.to_bits()),
                "rank {r} diverged at len {len}"
            );
        }
    }
}

/// Training over the in-process socket transport is bit-identical to the
/// channel backend — flat bucketed reduce on both (rpn only changes the
/// wire, not the schedule), then the hierarchical reduce on both.
#[test]
fn socket_train_bit_identical_to_channel() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let (inputs, targets) = make_cf_data(6, 8, 31);
    let src = Arc::new(InMemorySource { inputs, targets });
    let o = opts(SpatialGrid::depth(2), 2, 2, 4, 21);

    for reduce in [
        GradReduce::default(),
        GradReduce::Hier { bucket_elems: DEFAULT_BUCKET_ELEMS, ranks_per_node: 2 },
    ] {
        let chan = train_hybrid_with(&rt, &o, src.clone(), &CommBackend::Channel,
                                     reduce)
            .unwrap();
        let sock = train_hybrid_with(&rt, &o, src.clone(),
                                     &CommBackend::Socket { ranks_per_node: 2 },
                                     reduce)
            .unwrap();
        if let Err(e) = bit_identical(&chan, &sock) {
            panic!("channel vs socket ({reduce:?}): {e}");
        }
        assert_eq!(chan.socket_frame_bytes, 0);
        assert!(sock.socket_frame_bytes > 0,
                "socket run framed no inter-node traffic ({reduce:?})");
    }
}

/// Property: for random small configurations (grid up to 2x2x2, 1-2 data
/// groups, random seeds) the socket world reproduces the channel world
/// bit for bit — losses, parameters and byte counters.
#[test]
fn prop_socket_backend_equivalence() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let grids = [
        SpatialGrid::new(1, 1, 1),
        SpatialGrid::new(2, 1, 1),
        SpatialGrid::new(1, 2, 1),
        SpatialGrid::new(2, 2, 1),
        SpatialGrid::new(2, 2, 2),
    ];
    let usable: Vec<SpatialGrid> = grids
        .into_iter()
        .filter(|g| {
            rt.manifest()
                .model("cf-nano")
                .map(|m| m.hybrid_plan(g).is_ok())
                .unwrap_or(false)
        })
        .collect();
    assert!(!usable.is_empty(), "no cf-nano grid plans in artifacts");
    prop::check("socket-backend-equivalence", 4, |g| {
        let grid = *g.pick(&usable);
        let groups = g.usize_in(1, 2);
        let steps = g.usize_in(2, 3);
        let seed = g.usize_in(1, 1 << 20) as u64;
        let (inputs, targets) = make_cf_data(2 * groups + 2, 8, seed);
        let src = Arc::new(InMemorySource { inputs, targets });
        let o = opts(grid, groups, groups * g.usize_in(1, 2), steps, seed);
        let chan = train_hybrid_with(&rt, &o, src.clone(), &CommBackend::Channel,
                                     GradReduce::default())
            .map_err(|e| format!("channel: {e:#}"))?;
        let sock = train_hybrid_with(&rt, &o, src,
                                     &CommBackend::Socket { ranks_per_node: 2 },
                                     GradReduce::default())
            .map_err(|e| format!("socket: {e:#}"))?;
        bit_identical(&chan, &sock)
            .map_err(|e| format!("{} x{groups} seed {seed}: {e}", grid))
    });
}

fn hydra3d_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_hydra3d"))
}

/// Supervise a spawned launcher with our own deadline so a supervision bug
/// shows up as a test failure, not a hung test run.
fn wait_with_deadline(
    mut child: std::process::Child,
    secs: u64,
    what: &str,
) -> (std::process::ExitStatus, String, String) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    let status = loop {
        match child.try_wait().expect("try_wait") {
            Some(st) => break st,
            None if Instant::now() >= deadline => {
                child.kill().ok();
                child.wait().ok();
                panic!("{what} still running after {secs}s — launcher hung");
            }
            None => std::thread::sleep(Duration::from_millis(50)),
        }
    };
    let mut out = String::new();
    let mut err = String::new();
    if let Some(mut o) = child.stdout.take() {
        o.read_to_string(&mut out).ok();
    }
    if let Some(mut e) = child.stderr.take() {
        e.read_to_string(&mut err).ok();
    }
    (status, out, err)
}

/// Kill-the-child: when a worker process dies, the launcher must kill the
/// survivors and surface a clean error naming the dead node — not hang on
/// a rendezvous/collective that can never complete.
#[test]
fn launcher_surfaces_dead_worker_cleanly() {
    let child = hydra3d_bin()
        .args(["comm-smoke", "--world", "4", "--ranks-per-node", "2",
               "--elems", "64"])
        .env("HYDRA3D_TEST_DIE_NODE", "1")
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn comm-smoke");
    let (status, out, err) = wait_with_deadline(child, 60, "comm-smoke");
    assert!(!status.success(), "launcher exited 0 despite a dead worker\
                                \nstdout: {out}\nstderr: {err}");
    assert!(err.contains("worker for node 1 failed"),
            "error does not name the dead node\nstderr: {err}");
}

/// A real 4-process smoke run: two worker processes x two rank threads,
/// Unix-socket rendezvous, flat-ring + hierarchical allreduce. Exact frame
/// totals for 256 f32: ring 12 frames x 64 elems = 3216 B, hier 4 frames
/// x 128 elems = 2096 B (12 B header + 4 B/elem per frame).
#[test]
fn comm_smoke_four_process_run() {
    let child = hydra3d_bin()
        .args(["comm-smoke", "--world", "4", "--ranks-per-node", "2",
               "--elems", "256"])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn comm-smoke");
    let (status, out, err) = wait_with_deadline(child, 120, "comm-smoke");
    assert!(status.success(), "comm-smoke failed\nstdout: {out}\nstderr: {err}");
    assert!(out.contains("comm-smoke ok"), "stdout: {out}");
    assert!(out.contains("socket_ring_frame_bytes=3216"), "stdout: {out}");
    assert!(out.contains("socket_hier_frame_bytes=2096"), "stdout: {out}");
}

/// THE acceptance run: a 4-process `train --backend socket` CosmoFlow run
/// writes a bit-exact fingerprint identical to the channel backend's on
/// every field except `backend` and `socket_frame_bytes`. Both runs use
/// `--ranks-per-node 2`, i.e. the hierarchical gradient reduce, so the
/// schedules match exactly; the channel run executes it over threads, the
/// socket run over 2 worker processes x 2 ranks.
#[test]
fn cli_socket_report_matches_channel() {
    let Some(dir) = artifacts() else { return };
    let scratch = std::env::temp_dir()
        .join(format!("hydra3d-report-test-{}", std::process::id()));
    std::fs::create_dir_all(&scratch).unwrap();
    let chan_path = scratch.join("channel.json");
    let sock_path = scratch.join("socket.json");
    let common = ["train", "--model", "cf-nano", "--ways", "2", "--groups",
                  "2", "--batch", "2", "--steps", "3", "--samples", "6",
                  "--seed", "12", "--ranks-per-node", "2"];
    for (backend, path) in [("channel", &chan_path), ("socket", &sock_path)] {
        let child = hydra3d_bin()
            .args(common)
            .args(["--backend", backend, "--report",
                   path.to_str().unwrap()])
            .env("HYDRA3D_ARTIFACTS", &dir)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawn train");
        let (status, out, err) =
            wait_with_deadline(child, 300, "train --backend socket");
        assert!(status.success(),
                "{backend} train failed\nstdout: {out}\nstderr: {err}");
    }
    let chan = Json::parse_file(&chan_path).unwrap();
    let sock = Json::parse_file(&sock_path).unwrap();
    for key in ["schema", "world", "losses_bits", "comm_bytes", "halo_bytes",
                "ingest_bytes", "redist_bytes"] {
        assert_eq!(chan.req(key).unwrap(), sock.req(key).unwrap(),
                   "report field {key} differs between backends");
    }
    assert_eq!(chan.req("backend").unwrap().as_str().unwrap(), "channel");
    assert_eq!(sock.req("backend").unwrap().as_str().unwrap(), "socket");
    assert_eq!(chan.req("socket_frame_bytes").unwrap().as_usize().unwrap(), 0);
    assert!(sock.req("socket_frame_bytes").unwrap().as_usize().unwrap() > 0,
            "socket run framed no inter-node traffic");
    assert!(!chan.req("losses_bits").unwrap().as_arr().unwrap().is_empty());
    std::fs::remove_dir_all(&scratch).ok();
}
