//! THE core correctness claim of the reproduction: hybrid-parallel training
//! computes exactly what single-device training computes (§III-A — spatial
//! partitioning + halo exchange + distributed BN are *algebraic identities*,
//! not approximations).
//!
//! For fixed seeds we require, step for step:
//!   fused(dataparallel) == hybrid(1 way) == hybrid(2 ways) == hybrid(4 ways)
//! on losses and on every parameter after training (small fp tolerance for
//! reduction-order differences).

use hydra3d::comm::{CommBackend, GradReduce, TraceCollector};
use hydra3d::engine::dataparallel::{train_fused, FullSource, FusedOpts};
use hydra3d::engine::hybrid::{train_hybrid, train_hybrid_with, HybridOpts, InMemorySource};
use hydra3d::engine::{LrSchedule, TrainReport};
use hydra3d::partition::SpatialGrid;
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use hydra3d::util::rng::Pcg;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn rand_tensor(rng: &mut Pcg, shape: &[usize], sigma: f32) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), sigma);
    t
}

fn make_cf_data(n: usize, size: usize, seed: u64) -> (Vec<Tensor>, Vec<Tensor>) {
    let mut rng = Pcg::new(seed, 77);
    let mut inputs = Vec::new();
    let mut targets = Vec::new();
    for _ in 0..n {
        let x = rand_tensor(&mut rng, &[1, 1, size, size, size], 1.0);
        let m: f32 = x.data().iter().sum::<f32>() / x.numel() as f32;
        let s: f32 = x.data().iter().map(|v| v * v).sum::<f32>() / x.numel() as f32;
        inputs.push(x);
        targets.push(Tensor::from_vec(&[1, 4], vec![m, s, -m, 0.3]));
    }
    (inputs, targets)
}

fn assert_reports_match(a: &TrainReport, b: &TrainReport, tol: f32, what: &str) {
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert!(
            (ra.loss - rb.loss).abs() <= tol * ra.loss.abs().max(1.0),
            "{what}: step {} loss {} vs {}", ra.step, ra.loss, rb.loss
        );
    }
    for (i, (pa, pb)) in a.params.iter().zip(&b.params).enumerate() {
        let d = pa.rel_l2_diff(pb);
        assert!(d < tol, "{what}: param {i} rel diff {d}");
    }
}

fn hybrid_opts(model: &str, ways: usize, groups: usize, batch: usize, steps: usize)
               -> HybridOpts {
    grid_opts(model, SpatialGrid::depth(ways), groups, batch, steps)
}

fn grid_opts(model: &str, grid: SpatialGrid, groups: usize, batch: usize,
             steps: usize) -> HybridOpts {
    HybridOpts {
        model: model.into(),
        grid,
        groups,
        batch_global: batch,
        steps,
        seed: 21,
        schedule: LrSchedule { lr0: 2e-3, floor_frac: 0.1, total_steps: steps },
        log_every: 0,
        ckpt: None,
    }
}

/// True if the built artifacts carry a `dxhxw` grid shard set for `model`
/// (older artifact builds predate grid plans; skip with a note then).
fn has_grid_plan(rt: &RuntimeHandle, model: &str, grid: &SpatialGrid) -> bool {
    match rt.manifest().model(model) {
        Ok(info) => info.hybrid_plan(grid).is_ok(),
        Err(_) => false,
    }
}

/// hybrid(1 way) == hybrid(2 ways): the halo-exchange conv path is exact.
#[test]
fn hybrid_ways_equivalence_cf_nano() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let (inputs, targets) = make_cf_data(6, 8, 1);
    let src = Arc::new(InMemorySource { inputs, targets });
    let a = train_hybrid(&rt, &hybrid_opts("cf-nano", 1, 1, 2, 6), src.clone()).unwrap();
    let b = train_hybrid(&rt, &hybrid_opts("cf-nano", 2, 1, 2, 6), src).unwrap();
    assert_reports_match(&a, &b, 5e-4, "ways 1 vs 2");
}

/// hybrid == fused on the same schedule: the per-layer decomposition is the
/// same function as the fused jax graph.
#[test]
fn hybrid_matches_fused_cf_nano() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let (inputs, targets) = make_cf_data(6, 8, 2);
    let fsrc = Arc::new(FullSource { inputs: inputs.clone(), targets: targets.clone() });
    let hsrc = Arc::new(InMemorySource { inputs, targets });
    let fused = train_fused(
        &rt,
        &FusedOpts {
            model: "cf-nano".into(),
            groups: 1,
            batch_global: 2,
            steps: 6,
            seed: 21,
            schedule: LrSchedule { lr0: 2e-3, floor_frac: 0.1, total_steps: 6 },
            log_every: 0,
            ckpt: None,
        },
        fsrc,
    )
    .unwrap();
    let hybrid = train_hybrid(&rt, &hybrid_opts("cf-nano", 2, 1, 2, 6), hsrc).unwrap();
    assert_reports_match(&fused, &hybrid, 1e-3, "fused vs hybrid");
}

/// With batch normalization: distributed statistics across ways and groups
/// must reproduce the single-rank result. Instant batch = groups, so we
/// compare (groups=2, ways=1) vs (groups=2, ways=2) vs fused(batch=2).
#[test]
fn hybrid_bn_equivalence() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let (inputs, targets) = make_cf_data(6, 8, 3);
    let hsrc = Arc::new(InMemorySource {
        inputs: inputs.clone(),
        targets: targets.clone(),
    });
    let a = train_hybrid(&rt, &hybrid_opts("cf-nano-bn", 1, 2, 2, 5), hsrc.clone())
        .unwrap();
    let b = train_hybrid(&rt, &hybrid_opts("cf-nano-bn", 2, 2, 2, 5), hsrc.clone())
        .unwrap();
    assert_reports_match(&a, &b, 1e-3, "bn ways 1 vs 2");

    // fused BN normalizes over its local batch of 2 == the hybrid instant
    // batch (2 groups x 1 sample), same samples in the same order.
    let fused = train_fused(
        &rt,
        &FusedOpts {
            model: "cf-nano-bn".into(),
            groups: 1,
            batch_global: 2,
            steps: 5,
            seed: 21,
            schedule: LrSchedule { lr0: 2e-3, floor_frac: 0.1, total_steps: 5 },
            log_every: 0,
            ckpt: None,
        },
        Arc::new(FullSource { inputs, targets }),
    )
    .unwrap();
    assert_reports_match(&fused, &a, 2e-3, "bn fused vs hybrid");
}

/// 4-way partitioning on the 16^3 model, plus hybrid (groups x ways) at
/// once — the full "hybrid parallelism" configuration of Fig. 2.
#[test]
fn hybrid_4way_and_2x2_cf16() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let (inputs, targets) = make_cf_data(8, 16, 4);
    let src = Arc::new(InMemorySource { inputs, targets });
    let a = train_hybrid(&rt, &hybrid_opts("cf16", 1, 1, 2, 3), src.clone()).unwrap();
    let b = train_hybrid(&rt, &hybrid_opts("cf16", 4, 1, 2, 3), src.clone()).unwrap();
    assert_reports_match(&a, &b, 1e-3, "cf16 1 vs 4 ways");
    let c = train_hybrid(&rt, &hybrid_opts("cf16", 2, 2, 2, 3), src).unwrap();
    assert_reports_match(&a, &c, 1e-3, "cf16 1x1 vs 2x2");
}

/// 3D U-Net: deconv + skip connections + per-voxel loss under partitioning.
#[test]
fn hybrid_unet_ways_equivalence() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let mut rng = Pcg::new(9, 5);
    let mut inputs = Vec::new();
    let mut targets = Vec::new();
    for _ in 0..4 {
        let x = rand_tensor(&mut rng, &[1, 1, 16, 16, 16], 1.0);
        // one-hot labels from a threshold on the input
        let mut oh = Tensor::zeros(&[1, 2, 16, 16, 16]);
        for i in 0..x.numel() {
            let cls = usize::from(x.data()[i] > 0.0);
            oh.data_mut()[cls * x.numel() + i] = 1.0;
        }
        inputs.push(x);
        targets.push(oh);
    }
    let src = Arc::new(InMemorySource { inputs, targets });
    let a = train_hybrid(&rt, &hybrid_opts("unet16", 1, 1, 1, 3), src.clone()).unwrap();
    let b = train_hybrid(&rt, &hybrid_opts("unet16", 2, 1, 1, 3), src).unwrap();
    assert_reports_match(&a, &b, 1e-3, "unet 1 vs 2 ways");
    assert!(a.final_loss().is_finite());
}

/// All three communicator backends produce the same trajectory: channel
/// (default), loopback (single rank) and traced (channel + recording) must
/// match each other through the same equivalence harness the ways tests
/// use — the backends only move bytes, never change reduction orders.
#[test]
fn comm_backends_equivalent() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let (inputs, targets) = make_cf_data(6, 8, 7);
    let src = Arc::new(InMemorySource { inputs, targets });
    let channel = train_hybrid_with(
        &rt,
        &hybrid_opts("cf-nano", 1, 1, 2, 5),
        src.clone(),
        &CommBackend::Channel,
        GradReduce::default(),
    )
    .unwrap();
    let loopback = train_hybrid_with(
        &rt,
        &hybrid_opts("cf-nano", 1, 1, 2, 5),
        src.clone(),
        &CommBackend::Loopback,
        GradReduce::default(),
    )
    .unwrap();
    assert_reports_match(&channel, &loopback, 1e-6, "channel vs loopback");

    let tc = Arc::new(TraceCollector::new());
    let traced = train_hybrid_with(
        &rt,
        &hybrid_opts("cf-nano", 2, 1, 2, 5),
        src,
        &CommBackend::Traced(tc.clone()),
        GradReduce::default(),
    )
    .unwrap();
    assert_reports_match(&channel, &traced, 5e-4, "channel 1x1 vs traced 2-way");
    assert!(tc.message_count() > 0, "traced backend recorded nothing");
    assert!(!tc.collectives().is_empty());
}

/// Bucketed-overlap gradient allreduce computes the same training
/// trajectory as the monolithic end-of-step allreduce (different bucket
/// boundaries change float reduction order, nothing else).
#[test]
fn bucketed_overlap_matches_monolithic() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let (inputs, targets) = make_cf_data(6, 8, 8);
    let src = Arc::new(InMemorySource { inputs, targets });
    let mono = train_hybrid_with(
        &rt,
        &hybrid_opts("cf-nano", 2, 1, 2, 6),
        src.clone(),
        &CommBackend::Channel,
        GradReduce::Monolithic,
    )
    .unwrap();
    // tiny buckets force many launches; results must still agree
    let bucketed = train_hybrid_with(
        &rt,
        &hybrid_opts("cf-nano", 2, 1, 2, 6),
        src,
        &CommBackend::Channel,
        GradReduce::Bucketed { bucket_elems: 64 },
    )
    .unwrap();
    assert_reports_match(&mono, &bucketed, 5e-4, "monolithic vs bucketed");
    assert!(bucketed.phases.allreduce_overlapped > 0.0,
            "bucketed path did no worker-side allreduce");
}

/// THE 3D tentpole claim: a CosmoFlow-style model trained on a full
/// 2x2x2 spatial grid (8 ranks per sample) computes the same trajectory
/// as the single-rank engine — spatial partitioning along all three axes
/// plus sequential per-axis halo exchange is an algebraic identity.
#[test]
fn hybrid_grid_2x2x2_equivalence_cf_nano() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let grid = SpatialGrid::new(2, 2, 2);
    if !has_grid_plan(&rt, "cf-nano", &grid) {
        eprintln!("(artifacts predate grid shard sets; rebuild with \
                   `make artifacts` to run the 2x2x2 equivalence test)");
        return;
    }
    let (inputs, targets) = make_cf_data(6, 8, 11);
    let src = Arc::new(InMemorySource { inputs, targets });
    let a = train_hybrid(&rt, &grid_opts("cf-nano", SpatialGrid::depth(1), 1, 2, 6),
                         src.clone())
        .unwrap();
    let b = train_hybrid(&rt, &grid_opts("cf-nano", grid, 1, 2, 6), src).unwrap();
    // acceptance bar: loss trajectories within 1e-4 rel-L2 of single-rank
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        num += ((ra.loss - rb.loss) as f64).powi(2);
        den += (ra.loss as f64).powi(2);
    }
    let rel = (num.sqrt() / (den.sqrt() + 1e-12)) as f32;
    assert!(rel < 1e-4, "2x2x2 loss trajectory rel-L2 {rel} vs single rank");
    assert_reports_match(&a, &b, 1e-3, "cf-nano 1x1x1 vs 2x2x2");
    // all three axes moved halo faces
    assert!(b.halo_bytes.iter().all(|&x| x > 0),
            "per-axis halo bytes {:?}", b.halo_bytes);
}

/// The same claim for the U-Net-style model: deconv, skip connections and
/// the spatially partitioned per-voxel loss under a 2x2x2 grid.
#[test]
fn hybrid_grid_2x2x2_equivalence_unet() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let grid = SpatialGrid::new(2, 2, 2);
    if !has_grid_plan(&rt, "unet16", &grid) {
        eprintln!("(artifacts predate grid shard sets; rebuild with \
                   `make artifacts` to run the 2x2x2 U-Net test)");
        return;
    }
    let mut rng = Pcg::new(19, 5);
    let mut inputs = Vec::new();
    let mut targets = Vec::new();
    for _ in 0..4 {
        let x = rand_tensor(&mut rng, &[1, 1, 16, 16, 16], 1.0);
        let mut oh = Tensor::zeros(&[1, 2, 16, 16, 16]);
        for i in 0..x.numel() {
            let cls = usize::from(x.data()[i] > 0.0);
            oh.data_mut()[cls * x.numel() + i] = 1.0;
        }
        inputs.push(x);
        targets.push(oh);
    }
    let src = Arc::new(InMemorySource { inputs, targets });
    let a = train_hybrid(&rt, &grid_opts("unet16", SpatialGrid::depth(1), 1, 1, 3),
                         src.clone())
        .unwrap();
    let b = train_hybrid(&rt, &grid_opts("unet16", grid, 1, 1, 3), src).unwrap();
    assert_reports_match(&a, &b, 1e-3, "unet16 1x1x1 vs 2x2x2");
    assert!(b.final_loss().is_finite());
}

/// Hybrid training actually learns (loss decreases on a learnable task).
#[test]
fn hybrid_training_learns() {
    let Some(dir) = artifacts() else { return };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let (inputs, targets) = make_cf_data(8, 8, 6);
    let src = Arc::new(InMemorySource { inputs, targets });
    let mut opts = hybrid_opts("cf-nano", 2, 1, 2, 25);
    opts.schedule = LrSchedule { lr0: 3e-3, floor_frac: 0.1, total_steps: 25 };
    let rep = train_hybrid(&rt, &opts, src).unwrap();
    let first = rep.records[0].loss;
    let last = rep.final_loss();
    assert!(last < 0.5 * first, "hybrid did not learn: {first} -> {last}");
    assert!(rep.comm_bytes > 0);
    assert!(rep.phases.halo >= 0.0 && rep.phases.allreduce > 0.0);
}
