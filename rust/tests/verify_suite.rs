//! `hydra3d verify` end-to-end: random valid configurations extract clean
//! schedules (positive property), every seeded mutation class is caught
//! with the expected diagnostic (negative table), the synthetic store
//! issues the same redistribution schedule as a container-ingested one,
//! and — when AOT artifacts are present — the dry-run walker's streams
//! match the real engine's traced run op for op.

use hydra3d::analysis::{
    self, check_schedule, mutate, DefectKind, EngineKind, ModelSpec,
    MutationKind, VerifyCfg,
};
use hydra3d::comm::{CommBackend, GradReduce, TraceCollector};
use hydra3d::data::container::{write_dataset, Container};
use hydra3d::engine::hybrid::{train_hybrid_with, HybridOpts, InMemorySource, IoMode};
use hydra3d::engine::LrSchedule;
use hydra3d::iosim::store::{assignments_of, DataStore};
use hydra3d::partition::{GridTopology, SpatialGrid};
use hydra3d::runtime::RuntimeHandle;
use hydra3d::tensor::Tensor;
use hydra3d::util::prop;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

/// Positive property: any valid (model × grid × groups × io × reduce)
/// configuration extracts a schedule with zero defects. Grid dims are
/// drawn from {1, 2, 3} per axis (the built-in specs' extents are
/// divisible by all of them), groups from 1–4, all three I/O modes.
#[test]
fn prop_random_valid_configs_verify_clean() {
    prop::check("verify-clean", 12, |g| {
        let grid = SpatialGrid::new(
            g.usize_in(1, 3),
            g.usize_in(1, 3),
            g.usize_in(1, 3),
        );
        // bound the rank-thread count: 27-way grids run single-group
        let groups = if grid.ways() >= 18 { 1 } else { g.usize_in(1, 4) };
        let world = groups * grid.ways();
        let mut spec =
            ModelSpec::builtin(*g.pick(&["cf-sim", "cf-sim-bn", "unet-sim"]))
                .unwrap();
        if spec.has_bn() && world > 1 && !world.is_power_of_two() {
            // the BN statistics allreduce requires 2^k ranks; resample the
            // model rather than discarding the drawn topology
            spec = ModelSpec::builtin("cf-sim").unwrap();
        }
        let io = *g.pick(&[IoMode::InMem, IoMode::Store, IoMode::StoreAsync]);
        let reduce = if g.bool() {
            GradReduce::default()
        } else {
            GradReduce::Monolithic
        };
        let batch_global = groups * g.usize_in(1, 2);
        let cfg = VerifyCfg {
            grid,
            groups,
            batch_global,
            steps: g.usize_in(1, 2),
            samples: batch_global * g.usize_in(1, 2),
            seed: g.rng.next_u64(),
            io,
            reduce,
            engine: EngineKind::Hybrid,
        };
        let defects = analysis::verify(&spec, &cfg)
            .map_err(|e| format!("{} on {}: {e:#}", spec.name, cfg.describe()))?;
        if defects.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "{} on {}: {} defect(s), first: {}",
                spec.name,
                cfg.describe(),
                defects.len(),
                defects[0]
            ))
        }
    });
}

/// The fused data-parallel walker is clean for both reduction strategies
/// over 1–4 groups (a smaller space — enumerate it).
#[test]
fn fused_configs_verify_clean() {
    for groups in 1..=4usize {
        for reduce in [GradReduce::default(), GradReduce::Monolithic] {
            let spec = ModelSpec::builtin("cf-sim").unwrap();
            let cfg = VerifyCfg {
                grid: SpatialGrid::new(1, 1, 1),
                groups,
                batch_global: 2 * groups,
                steps: 2,
                samples: 4 * groups,
                seed: 3,
                io: IoMode::InMem,
                reduce,
                engine: EngineKind::Fused,
            };
            let defects = analysis::verify(&spec, &cfg).unwrap();
            assert!(defects.is_empty(), "{}: {:?}", cfg.describe(), defects);
        }
    }
}

/// Negative table: every mutation class, applied to the baseline schedule,
/// must be reported with its expected [`DefectKind`] and with rank / op /
/// detail context populated (tag and peer too for point-to-point kinds).
#[test]
fn every_mutation_class_is_caught_with_context() {
    let (spec, cfg) = VerifyCfg::mutation_baseline();
    let baseline = analysis::extract(&spec, &cfg).unwrap();
    assert!(
        check_schedule(&baseline).is_empty(),
        "mutation baseline must be clean"
    );
    let world = cfg.groups * cfg.grid.ways();
    for (round, kind) in MutationKind::ALL.iter().enumerate() {
        let mut mutated = baseline.clone();
        let desc = mutate::apply(&mut mutated, *kind, 100 + round as u64)
            .unwrap_or_else(|e| panic!("{}: no site: {e:#}", kind.name()));
        let defects = check_schedule(&mutated);
        let hit = defects
            .iter()
            .find(|d| d.kind == kind.expected())
            .unwrap_or_else(|| {
                panic!(
                    "{} ({desc}) not reported as {:?}; got {defects:?}",
                    kind.name(),
                    kind.expected()
                )
            });
        // diagnostic context: a defect must name where and what
        assert!(hit.rank < world, "{}: rank out of range", kind.name());
        assert!(!hit.op.is_empty(), "{}: empty op", kind.name());
        assert!(!hit.detail.is_empty(), "{}: empty detail", kind.name());
        let p2p = matches!(
            kind.expected(),
            DefectKind::UnmatchedSend
                | DefectKind::UnmatchedRecv
                | DefectKind::ByteMismatch
                | DefectKind::TagMismatch
                | DefectKind::TagAliasing
                | DefectKind::Deadlock
        );
        if p2p {
            assert!(hit.peer.is_some(), "{}: missing peer", kind.name());
            assert!(hit.tag.is_some(), "{}: missing tag", kind.name());
        }
    }
}

/// The packaged harness: multiple rounds per class, distinct seeds, all
/// caught — the acceptance gate `hydra3d verify --mutations` runs in CI.
#[test]
fn mutation_suite_catches_every_round() {
    let outcomes = analysis::run_mutation_suite(5, 2).unwrap();
    assert_eq!(outcomes.len(), 2 * MutationKind::ALL.len());
    let missed: Vec<_> = outcomes.iter().filter(|o| !o.caught).collect();
    assert!(missed.is_empty(), "escaped mutations: {missed:?}");
    let kinds: std::collections::HashSet<_> =
        outcomes.iter().map(|o| o.kind.expected()).collect();
    assert!(kinds.len() >= 8, "fewer than 8 distinct defect classes");
}

/// The synthetic store must issue the exact redistribution schedule of a
/// container-ingested store with the same geometry — that is what makes
/// artifact-free `verify` runs trustworthy for redistribution traffic.
#[test]
fn synthetic_store_matches_ingested_redistribution() {
    let topo = GridTopology::new(2, SpatialGrid::new(2, 1, 1));
    let n = topo.world_size();
    let size = 8usize;
    let n_samples = 4usize;
    let inputs: Vec<Tensor> =
        (0..n_samples).map(|_| Tensor::zeros(&[1, 1, size, size, size])).collect();
    let targets: Vec<Tensor> =
        (0..n_samples).map(|_| Tensor::zeros(&[1, 4])).collect();
    let mut path = std::env::temp_dir();
    path.push(format!("hydra3d-verify-parity-{}", std::process::id()));
    write_dataset(&path, &inputs, &targets, None).unwrap();
    let container = Container::open(&path).unwrap();

    // two identical steps' worth of group-major schedule rows
    let rows: Vec<Vec<usize>> = vec![vec![0, 2, 1, 3], vec![3, 1, 2, 0]];

    let run = |mut stores: Vec<DataStore>| -> Vec<Vec<hydra3d::comm::ScheduleOp>> {
        let tc = Arc::new(TraceCollector::new());
        let eps = CommBackend::Traced(tc.clone()).build_world(n).unwrap();
        thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .zip(stores.drain(..))
                .map(|(ep, mut st)| {
                    let rows = &rows;
                    s.spawn(move || {
                        for row in rows {
                            let assigns = assignments_of(row, st.topo.groups);
                            st.redistribute(ep.as_ref(), &assigns).unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        tc.op_streams()
    };

    let ingested = run((0..n)
        .map(|r| DataStore::ingest(&container, topo, r, false).unwrap())
        .collect());
    let synthetic = run((0..n)
        .map(|r| DataStore::synthetic(topo, r, n_samples, size, 1, 4, 0, false)
            .unwrap())
        .collect());
    std::fs::remove_file(&path).ok();
    assert_eq!(ingested, synthetic, "redistribution schedules diverge");
}

/// Artifact-gated walker-fidelity check: the dry-run extraction must
/// reproduce the real hybrid engine's traced communication streams op for
/// op (compute world and gradient world) for a production model plan.
#[test]
fn dry_run_matches_real_hybrid_schedule() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: no artifacts built");
        return;
    };
    let rt = RuntimeHandle::start(&dir).unwrap();
    let model = "cf16";
    let Ok(info) = rt.manifest().model(model) else {
        eprintln!("skipping: no {model} in manifest");
        return;
    };
    let grid = SpatialGrid::new(2, 1, 1);
    if info.hybrid_plan(&grid).is_err() {
        eprintln!("skipping: no {grid} plan for {model}");
        return;
    }
    let info = info.clone();
    let n = 2; // 1 group x 2-way depth grid
    let steps = 2;
    let batch = 2;
    let seed = 21;
    let samples = 4;

    // real run over one traced backend: compute endpoints get ids 0..n,
    // gradient endpoints n..2n (build_world then build_grad_world order)
    let size = info.input_size;
    let inputs: Vec<Tensor> =
        (0..samples).map(|_| Tensor::zeros(&[1, 1, size, size, size])).collect();
    let targets: Vec<Tensor> =
        (0..samples).map(|_| Tensor::zeros(&[1, info.n_targets])).collect();
    let tc = Arc::new(TraceCollector::new());
    let opts = HybridOpts {
        model: model.into(),
        grid,
        groups: 1,
        batch_global: batch,
        steps,
        seed,
        schedule: LrSchedule { lr0: 1e-3, floor_frac: 0.1, total_steps: steps },
        log_every: 0,
        ckpt: None,
    };
    train_hybrid_with(
        &rt,
        &opts,
        Arc::new(InMemorySource { inputs, targets }),
        &CommBackend::Traced(tc.clone()),
        GradReduce::default(),
    )
    .unwrap();
    let real = tc.op_streams();

    let spec = ModelSpec::from_model_info(&info);
    let cfg = VerifyCfg {
        grid,
        groups: 1,
        batch_global: batch,
        steps,
        samples,
        seed,
        io: IoMode::InMem,
        reduce: GradReduce::default(),
        engine: EngineKind::Hybrid,
    };
    let sched = analysis::extract(&spec, &cfg).unwrap();
    let compute = &sched.world("compute").unwrap().ranks;
    let grad = &sched.world("grad").unwrap().ranks;
    for r in 0..n {
        assert_eq!(
            compute[r], real[r],
            "compute stream of rank {r} diverges from the real engine"
        );
        assert_eq!(
            grad[r],
            real[n + r],
            "grad stream of rank {r} diverges from the real engine"
        );
    }
    assert!(check_schedule(&sched).is_empty());
}
